"""Paged KV cache bookkeeping: a free-list block allocator + block tables.

The device side of the paged cache is a fixed-shape block pool per layer
(:func:`repro.models.transformer.lm_paged_cache_init`): P = n_blocks ×
block_size KV rows, where block ``i`` owns rows [i*bs, (i+1)*bs).  This
module owns the HOST side: which blocks belong to which batch slot.  It
is plain numpy/python — no jax — so admission decisions never touch the
device, and the only thing the decode step uploads per iteration is the
small (slots, max_blocks) int32 table.

Design points (the paged-attention serving pattern):

* **Fixed pool, free-list reuse.**  Blocks are preallocated once; alloc
  pops from a LIFO free list and free pushes back, so slot churn reuses
  hot HBM rows instead of fragmenting them.  Allocation order is
  deterministic — byte-parity tests lean on a freed-and-reused table
  producing the same gathers as a fresh one.
* **Trash block 0.**  Table entries of unallocated positions (and whole
  rows of inactive slots) point at reserved block 0.  Writes from masked
  lanes land there harmlessly; reads from it are always masked by the
  position-validity mask (``idx <= pos``), so its contents are never
  observable.
* **Reserve-at-admission.**  ``admit(slot, total_len)`` reserves every
  block the request can touch (prompt + decode budget) up front.  A
  request therefore either admits whole or waits — pool exhaustion is
  admission backpressure, never a mid-decode stall that would need
  preemption machinery.  (On-demand growth exists as ``grow`` for the
  cache tests and future prefix-sharing work.)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["BlockManager", "PagedCacheSpec", "TRASH_BLOCK", "blocks_for"]

TRASH_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV rows."""
    return max(0, -(-int(n_tokens) // block_size))


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """Static geometry of one paged cache pool."""

    n_blocks: int           # total blocks incl. the reserved trash block
    block_size: int
    max_slots: int          # decode batch width
    max_blocks_per_seq: int # block-table width M (view length = M * bs)

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 2:
            raise ValueError("n_blocks must be >= 2 (block 0 is the trash block)")
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_blocks_per_seq < 1:
            raise ValueError("max_blocks_per_seq must be >= 1")

    @property
    def max_len(self) -> int:
        """Longest sequence (prompt + generated) a slot can address."""
        return self.max_blocks_per_seq * self.block_size

    @property
    def usable_blocks(self) -> int:
        return self.n_blocks - 1  # minus trash

    @property
    def pool_rows(self) -> int:
        return self.n_blocks * self.block_size


class BlockManager:
    """Free-list allocator + per-slot block tables over a fixed pool."""

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        # LIFO free list: lowest ids allocated first ⇒ deterministic reuse
        self._free: List[int] = list(range(spec.n_blocks - 1, 0, -1))
        self._allocated: set[int] = set()
        self._tables = np.full(
            (spec.max_slots, spec.max_blocks_per_seq), TRASH_BLOCK, np.int32
        )
        self._slot_blocks: Dict[int, List[int]] = {}
        self.allocs = 0
        self.frees = 0
        self.alloc_failures = 0     # admission attempts refused (backpressure)
        self.peak_in_use = 0

    # -- raw block ops -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._allocated)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks, or None (and count a failure) if short."""
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._allocated.update(blocks)
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, len(self._allocated))
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("refusing to free the trash block")
            if b not in self._allocated:
                raise ValueError(f"double free of block {b}")
            self._allocated.remove(b)
            self._free.append(b)
            self.frees += 1

    # -- slot lifecycle ------------------------------------------------------

    def can_admit(self, total_len: int) -> bool:
        """Would ``admit`` succeed for a sequence of ``total_len`` tokens?"""
        need = blocks_for(total_len, self.spec.block_size)
        return need <= self.spec.max_blocks_per_seq and need <= len(self._free)

    def admit(self, slot: int, total_len: int) -> bool:
        """Reserve every block of a ``total_len``-token sequence for ``slot``.

        Returns False (and leaves state untouched) when the pool can't
        cover it — the caller keeps the request queued.
        """
        if slot in self._slot_blocks:
            raise ValueError(f"slot {slot} is already admitted")
        need = blocks_for(total_len, self.spec.block_size)
        if need > self.spec.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {total_len} tokens needs {need} blocks > "
                f"table width {self.spec.max_blocks_per_seq} "
                f"(max_len {self.spec.max_len})"
            )
        blocks = self.alloc(need)
        if blocks is None:
            return False
        self._slot_blocks[slot] = blocks
        self._tables[slot, :] = TRASH_BLOCK
        self._tables[slot, : len(blocks)] = blocks
        return True

    def grow(self, slot: int, total_len: int) -> bool:
        """Extend ``slot`` to cover ``total_len`` tokens (on-demand mode)."""
        owned = self._slot_blocks.get(slot)
        if owned is None:
            raise ValueError(f"slot {slot} is not admitted")
        need = blocks_for(total_len, self.spec.block_size)
        if need > self.spec.max_blocks_per_seq:
            raise ValueError(f"slot {slot}: {need} blocks exceed table width")
        extra = need - len(owned)
        if extra <= 0:
            return True
        blocks = self.alloc(extra)
        if blocks is None:
            return False
        self._tables[slot, len(owned): len(owned) + extra] = blocks
        owned.extend(blocks)
        return True

    def release(self, slot: int) -> None:
        """Return a finished slot's blocks to the free list."""
        blocks = self._slot_blocks.pop(slot, None)
        if blocks is None:
            raise ValueError(f"slot {slot} is not admitted")
        self.free(blocks)
        self._tables[slot, :] = TRASH_BLOCK

    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._slot_blocks.get(slot, []))

    @property
    def tables(self) -> np.ndarray:
        """The (max_slots, M) int32 block tables (live view — upload, don't
        mutate)."""
        return self._tables

    def check(self) -> None:
        """Assert the allocator invariants (tests + debug)."""
        owned = [b for bs in self._slot_blocks.values() for b in bs]
        assert len(owned) == len(set(owned)), "block owned by two slots"
        # raw alloc() without a slot assignment is legal (mid-admission),
        # but a slot must never own a block the allocator doesn't know
        assert set(owned) <= self._allocated, "slot owns unallocated block"
        assert not (set(self._free) & self._allocated), "block both free and used"
        assert len(self._free) + len(self._allocated) == self.spec.n_blocks - 1
        assert TRASH_BLOCK not in self._allocated
        live = set(np.unique(self._tables)) - {TRASH_BLOCK}
        assert live <= self._allocated, "table points at unallocated block"

    def stats(self) -> Dict[str, int]:
        return {
            "n_blocks": self.spec.n_blocks,
            "block_size": self.spec.block_size,
            "in_use": self.n_in_use,
            "free": self.n_free,
            "allocs": self.allocs,
            "frees": self.frees,
            "alloc_failures": self.alloc_failures,
            "peak_in_use": self.peak_in_use,
        }
