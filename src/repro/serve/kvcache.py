"""Paged KV cache bookkeeping: a free-list block allocator + block tables.

The device side of the paged cache is a fixed-shape block pool per layer
(:func:`repro.models.transformer.lm_paged_cache_init`): P = n_blocks ×
block_size KV rows, where block ``i`` owns rows [i*bs, (i+1)*bs).  This
module owns the HOST side: which blocks belong to which batch slot.  It
is plain numpy/python — no jax — so admission decisions never touch the
device, and the only thing the decode step uploads per iteration is the
small (slots, max_blocks) int32 table.

Design points (the paged-attention serving pattern):

* **Fixed pool, free-list reuse.**  Blocks are preallocated once; alloc
  pops from a LIFO free list and free pushes back, so slot churn reuses
  hot HBM rows instead of fragmenting them.  Allocation order is
  deterministic — byte-parity tests lean on a freed-and-reused table
  producing the same gathers as a fresh one.
* **Trash block 0.**  Table entries of unallocated positions (and whole
  rows of inactive slots) point at reserved block 0.  Writes from masked
  lanes land there harmlessly; reads from it are always masked by the
  position-validity mask (``idx <= pos``), so its contents are never
  observable.
* **Reserve-at-admission.**  ``admit(slot, total_len)`` reserves every
  block the request can touch (prompt + decode budget) up front.  A
  request therefore either admits whole or waits — pool exhaustion is
  admission backpressure, never a mid-decode stall that would need
  preemption machinery.  (On-demand growth exists as ``grow`` for the
  cache tests.)
* **Refcounted sharing + copy-on-write.**  Every allocated block carries
  a refcount: one per slot that addresses it and one per
  :class:`PrefixIndex` entry that keeps it resident.  ``ref``/``unref``
  move a block between holders; a block returns to the free list only at
  refcount 0, and ``fork`` swaps a shared block out of a slot's table
  for a private copy (the caller copies the device rows) so a write can
  never be observed through another holder's table.  The trash block is
  never refcounted and never shared.

:class:`PrefixIndex` is the deduplication layer on top: the serving
analogue of the paper's byte-offset index.  Where the index maps an
InChI key to the byte span that already holds its record (so extraction
never re-reads what it has), the prefix index maps a rolling hash of
full token blocks to the resident block chain that already holds that
prompt prefix's KV — so admission adopts the blocks (refcount bump)
instead of re-running prefill over them.  Entries verify the exact
token prefix before adoption (a hash collision is a miss, never a wrong
share), and LRU eviction drops index-only (refcount-1) entries under
pool pressure.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "BlockManager",
    "PagedCacheSpec",
    "PrefixIndex",
    "TRASH_BLOCK",
    "blocks_for",
    "rolling_block_hashes",
]

TRASH_BLOCK = 0

_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer — deterministic across processes (unlike
    ``hash``), cheap enough for ≤32-token blocks."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def rolling_block_hashes(
    tokens: Sequence[int], block_size: int, n_blocks: int
) -> List[int]:
    """Rolling hash per full token block: ``out[j]`` covers blocks 0..j.

    Sequential fold (order-sensitive), so hash j+1 extends hash j without
    rescanning the prefix — probing every block-aligned prefix length of
    a prompt costs one pass over the prompt.
    """
    out: List[int] = []
    h = _mix64(block_size)
    for j in range(n_blocks):
        for t in tokens[j * block_size: (j + 1) * block_size]:
            h = _mix64(h ^ (int(t) & _M64))
        out.append(h)
    return out


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` KV rows."""
    return max(0, -(-int(n_tokens) // block_size))


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """Static geometry of one paged cache pool."""

    n_blocks: int           # total blocks incl. the reserved trash block
    block_size: int
    max_slots: int          # decode batch width
    max_blocks_per_seq: int # block-table width M (view length = M * bs)

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 2:
            raise ValueError("n_blocks must be >= 2 (block 0 is the trash block)")
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_blocks_per_seq < 1:
            raise ValueError("max_blocks_per_seq must be >= 1")

    @property
    def max_len(self) -> int:
        """Longest sequence (prompt + generated) a slot can address."""
        return self.max_blocks_per_seq * self.block_size

    @property
    def usable_blocks(self) -> int:
        return self.n_blocks - 1  # minus trash

    @property
    def pool_rows(self) -> int:
        return self.n_blocks * self.block_size


class BlockManager:
    """Free-list allocator + per-slot block tables over a fixed pool.

    Blocks are refcounted: ``alloc`` hands them out at refcount 1, ``ref``
    adds a holder (another slot's table, a prefix-index entry), ``unref``
    drops one and returns the block to the free list only at refcount 0.
    ``fork`` swaps a shared block out of one slot's table for a fresh
    private block (copy-on-write — the caller copies the device rows).
    """

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        # LIFO free list: lowest ids allocated first ⇒ deterministic reuse
        self._free: List[int] = list(range(spec.n_blocks - 1, 0, -1))
        self._allocated: set[int] = set()
        self._refcounts: Dict[int, int] = {}
        self._tables = np.full(
            (spec.max_slots, spec.max_blocks_per_seq), TRASH_BLOCK, np.int32
        )
        self._slot_blocks: Dict[int, List[int]] = {}
        self.allocs = 0
        self.frees = 0
        self.alloc_failures = 0     # admission attempts refused (backpressure)
        self.peak_in_use = 0

    # -- raw block ops -------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return len(self._allocated)

    def refcount(self, block: int) -> int:
        return self._refcounts.get(block, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` blocks at refcount 1, or None (and count a failure) if
        short."""
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._allocated.update(blocks)
        for b in blocks:
            self._refcounts[b] = 1
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, len(self._allocated))
        return blocks

    def ref(self, blocks: Sequence[int]) -> None:
        """Add one holder to each block (shared adoption)."""
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("refusing to share the trash block")
            if b not in self._allocated:
                raise ValueError(f"ref of unallocated block {b}")
        for b in blocks:
            self._refcounts[b] += 1

    def unref(self, blocks: Sequence[int]) -> int:
        """Drop one holder from each block; free those reaching refcount 0.

        Returns the number of blocks actually freed.
        """
        freed = 0
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("refusing to unref the trash block")
            rc = self._refcounts.get(b, 0)
            if b not in self._allocated or rc < 1:
                raise ValueError(f"unref of block {b} with no holders")
            if rc == 1:
                del self._refcounts[b]
                self._allocated.remove(b)
                self._free.append(b)
                self.frees += 1
                freed += 1
            else:
                self._refcounts[b] = rc - 1
        return freed

    def free(self, blocks: List[int]) -> None:
        """Return exclusively-held blocks to the free list.

        Shared blocks must go through ``unref`` — freeing one out from
        under another holder is always a bug, so it raises here.
        """
        for b in blocks:
            if b == TRASH_BLOCK:
                raise ValueError("refusing to free the trash block")
            if b not in self._allocated:
                raise ValueError(f"double free of block {b}")
            if self._refcounts.get(b, 0) > 1:
                raise ValueError(f"refusing to free shared block {b} "
                                 f"(refcount {self._refcounts[b]})")
        self.unref(blocks)

    def fork(self, slot: int, block_idx: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: give ``slot`` a private copy of table entry
        ``block_idx`` before it writes there.

        Returns ``(old, new)`` block ids — the caller copies the device
        rows old→new.  When the block is already exclusive this is a
        no-op ``(b, b)``; when the pool is empty returns None.
        """
        owned = self._slot_blocks.get(slot)
        if owned is None:
            raise ValueError(f"slot {slot} is not admitted")
        if not (0 <= block_idx < len(owned)):
            raise ValueError(f"slot {slot} has no block index {block_idx}")
        b = owned[block_idx]
        if self._refcounts.get(b, 0) <= 1:
            return (b, b)
        fresh = self.alloc(1)
        if fresh is None:
            return None
        new = fresh[0]
        self.unref([b])
        owned[block_idx] = new
        self._tables[slot, block_idx] = new
        return (b, new)

    # -- slot lifecycle ------------------------------------------------------

    def can_admit(self, total_len: int, n_adopted: int = 0) -> bool:
        """Would ``admit`` succeed for a sequence of ``total_len`` tokens,
        ``n_adopted`` of whose blocks are adopted from the prefix index?"""
        need = blocks_for(total_len, self.spec.block_size)
        return (need <= self.spec.max_blocks_per_seq
                and need - n_adopted <= len(self._free))

    def admit(
        self,
        slot: int,
        total_len: int,
        prefix_blocks: Optional[Sequence[int]] = None,
    ) -> bool:
        """Reserve every block of a ``total_len``-token sequence for ``slot``.

        ``prefix_blocks`` are already-resident shared blocks (from a
        :class:`PrefixIndex` match) adopted as the head of the slot's
        chain: they are ref'd, not allocated, and only the remainder
        comes off the free list.  Returns False (and leaves state
        untouched) when the pool can't cover the remainder — the caller
        keeps the request queued.
        """
        if slot in self._slot_blocks:
            raise ValueError(f"slot {slot} is already admitted")
        adopted = list(prefix_blocks or [])
        need = blocks_for(total_len, self.spec.block_size)
        if need > self.spec.max_blocks_per_seq:
            raise ValueError(
                f"sequence of {total_len} tokens needs {need} blocks > "
                f"table width {self.spec.max_blocks_per_seq} "
                f"(max_len {self.spec.max_len})"
            )
        if len(adopted) > need:
            raise ValueError(
                f"{len(adopted)} adopted blocks exceed the {need} the "
                f"sequence needs"
            )
        # whole-or-nothing: check the free list before taking any refs
        if need - len(adopted) > len(self._free):
            self.alloc_failures += 1
            return False
        self.ref(adopted)
        fresh = self.alloc(need - len(adopted))
        assert fresh is not None  # checked above
        blocks = adopted + fresh
        self._slot_blocks[slot] = blocks
        self._tables[slot, :] = TRASH_BLOCK
        self._tables[slot, : len(blocks)] = blocks
        return True

    def grow(self, slot: int, total_len: int) -> bool:
        """Extend ``slot`` to cover ``total_len`` tokens (on-demand mode)."""
        owned = self._slot_blocks.get(slot)
        if owned is None:
            raise ValueError(f"slot {slot} is not admitted")
        need = blocks_for(total_len, self.spec.block_size)
        if need > self.spec.max_blocks_per_seq:
            raise ValueError(f"slot {slot}: {need} blocks exceed table width")
        extra = need - len(owned)
        if extra <= 0:
            return True
        blocks = self.alloc(extra)
        if blocks is None:
            return False
        self._tables[slot, len(owned): len(owned) + extra] = blocks
        owned.extend(blocks)
        return True

    def release(self, slot: int) -> None:
        """Drop a finished slot's hold on its blocks.

        Exclusive blocks return to the free list; blocks still held by
        other slots or the prefix index merely lose one refcount.
        """
        blocks = self._slot_blocks.pop(slot, None)
        if blocks is None:
            raise ValueError(f"slot {slot} is not admitted")
        self.unref(blocks)
        self._tables[slot, :] = TRASH_BLOCK

    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._slot_blocks.get(slot, []))

    @property
    def tables(self) -> np.ndarray:
        """The (max_slots, M) int32 block tables (live view — upload, don't
        mutate)."""
        return self._tables

    def check(self, external_refs: Optional[Dict[int, int]] = None) -> None:
        """Assert the allocator invariants (tests + debug).

        ``external_refs`` maps block → refs held by non-slot holders
        (e.g. :meth:`PrefixIndex.block_refs`); when given, refcounts are
        validated *exactly* — slot holds + external holds must equal the
        recorded refcount for every allocated block.
        """
        owned: Dict[int, int] = {}
        for bs in self._slot_blocks.values():
            # a slot's own chain never repeats a block
            assert len(bs) == len(set(bs)), "slot chain repeats a block"
            for b in bs:
                owned[b] = owned.get(b, 0) + 1
        # raw alloc() without a slot assignment is legal (mid-admission),
        # but a slot must never own a block the allocator doesn't know
        assert set(owned) <= self._allocated, "slot owns unallocated block"
        assert not (set(self._free) & self._allocated), "block both free and used"
        assert len(self._free) + len(self._allocated) == self.spec.n_blocks - 1
        assert TRASH_BLOCK not in self._allocated
        live = set(np.unique(self._tables)) - {TRASH_BLOCK}
        assert live <= self._allocated, "table points at unallocated block"
        # refcount consistency
        assert set(self._refcounts) == self._allocated, \
            "refcounts out of sync with allocated set"
        for b, rc in self._refcounts.items():
            assert rc >= 1, f"allocated block {b} has refcount {rc}"
            held = owned.get(b, 0)
            if external_refs is None:
                assert held <= rc, \
                    f"block {b}: {held} slot holders exceed refcount {rc}"
            else:
                total = held + external_refs.get(b, 0)
                assert total == rc, (
                    f"block {b}: refcount {rc} != {held} slot holders + "
                    f"{external_refs.get(b, 0)} external refs"
                )
        if external_refs is not None:
            assert set(external_refs) <= self._allocated, \
                "external ref on unallocated block"

    def stats(self) -> Dict[str, int]:
        return {
            "n_blocks": self.spec.n_blocks,
            "block_size": self.spec.block_size,
            "in_use": self.n_in_use,
            "free": self.n_free,
            "allocs": self.allocs,
            "frees": self.frees,
            "alloc_failures": self.alloc_failures,
            "peak_in_use": self.peak_in_use,
            "shared_blocks": sum(1 for rc in self._refcounts.values() if rc > 1),
        }


class PrefixIndex:
    """Hash index over block-aligned prompt prefixes → resident block chains.

    Each entry is keyed by the rolling hash of its full token blocks and
    holds one refcount on every block of its chain, so the KV stays
    resident after the owning slot finishes.  ``match`` verifies the
    exact token prefix before reporting a hit (hash collisions are
    misses, never wrong adoptions) and refreshes LRU order;
    ``evict_for`` walks LRU→MRU under pool pressure, dropping only
    entries that actually return blocks to the free list (i.e. contain
    refcount-1 blocks) — an entry shared with an active slot is skipped,
    never freed out from under it.
    """

    def __init__(self, mgr: BlockManager, max_entries: Optional[int] = None):
        self.mgr = mgr
        self.block_size = mgr.spec.block_size
        self.max_entries = max_entries
        # hash → (token tuple, block chain); insertion/touch order = LRU
        self._entries: "OrderedDict[int, Tuple[Tuple[int, ...], List[int]]]" = (
            OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0
        self.hash_collisions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def match(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest resident block-aligned prefix of ``prompt``.

        Returns ``(blocks, n_tokens)`` with ``n_tokens = len(blocks) *
        block_size``, or ``([], 0)`` on a miss.  Adoption is capped at
        ``(len(prompt) - 1) // block_size`` blocks so at least one
        prompt token is always left to prefill (the suffix pass is what
        produces the last-position logits).  The returned blocks are NOT
        ref'd — pass them to :meth:`BlockManager.admit` as
        ``prefix_blocks`` before anything else can evict them.
        """
        bs = self.block_size
        n_full = (len(prompt) - 1) // bs
        if n_full <= 0 or not self._entries:
            self.misses += 1
            return [], 0
        hashes = rolling_block_hashes(prompt, bs, n_full)
        for j in range(n_full - 1, -1, -1):
            ent = self._entries.get(hashes[j])
            if ent is None:
                continue
            tokens, blocks = ent
            if tokens != tuple(int(t) for t in prompt[: (j + 1) * bs]):
                self.hash_collisions += 1
                continue
            self._entries.move_to_end(hashes[j])
            self.hits += 1
            return list(blocks), (j + 1) * bs
        self.misses += 1
        return [], 0

    def publish(
        self, prompt: Sequence[int], blocks: Sequence[int], n_tokens: int
    ) -> int:
        """Register every full-block prefix of ``prompt[:n_tokens]`` whose
        KV lives in ``blocks``.

        Each new entry refs its whole chain (blocks 0..j), keeping the
        prefix resident independent of the publishing slot's lifetime.
        Returns the number of entries inserted.
        """
        bs = self.block_size
        n_full = min(int(n_tokens) // bs, len(blocks))
        if n_full <= 0:
            return 0
        hashes = rolling_block_hashes(prompt, bs, n_full)
        added = 0
        for j in range(n_full):
            key = hashes[j]
            tokens = tuple(int(t) for t in prompt[: (j + 1) * bs])
            ent = self._entries.get(key)
            if ent is not None:
                if ent[0] != tokens:
                    self.hash_collisions += 1  # keep the resident entry
                else:
                    self._entries.move_to_end(key)
                continue
            if self.max_entries is not None and len(self._entries) >= self.max_entries:
                if self.evict_lru() == 0:
                    break
            chain = [int(b) for b in blocks[: j + 1]]
            self.mgr.ref(chain)
            self._entries[key] = (tokens, chain)
            self.inserts += 1
            added += 1
        return added

    def _drop(self, key: int) -> int:
        """Remove one entry, unref its chain; returns blocks freed."""
        _, chain = self._entries.pop(key)
        self.evictions += 1
        return self.mgr.unref(chain)

    def evict_lru(self) -> int:
        """Evict the least-recently-used droppable entry (one with at
        least one refcount-1 block).  Returns blocks freed (0 = nothing
        droppable)."""
        for key, (_, chain) in self._entries.items():
            if any(self.mgr.refcount(b) == 1 for b in chain):
                return self._drop(key)
        return 0

    def evict_for(self, need: int) -> int:
        """Free at least ``need`` blocks by LRU eviction, if possible.

        Walks LRU→MRU repeatedly; entries whose blocks are all shared
        with live holders are skipped (evicting them frees nothing and
        loses index coverage).  Returns the number of blocks freed,
        which may be < ``need`` when the index runs dry.
        """
        freed = 0
        while freed < need:
            got = self.evict_lru()
            if got == 0:
                break
            freed += got
        return freed

    def clear(self) -> int:
        """Drop every entry (shutdown / tests).  Returns blocks freed."""
        freed = 0
        for key in list(self._entries):
            freed += self._drop(key)
        return freed

    def block_refs(self) -> Dict[int, int]:
        """Refs held by the index per block — feed to
        :meth:`BlockManager.check` for exact refcount validation."""
        refs: Dict[int, int] = {}
        for _, chain in self._entries.values():
            for b in chain:
                refs[b] = refs.get(b, 0) + 1
        return refs

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "hash_collisions": self.hash_collisions,
        }
