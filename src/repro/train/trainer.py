"""End-to-end trainer: index-backed data → jitted train_step → catalog
checkpoints, with restart/elastic recovery built in.

This is the driver behind ``examples/train_indexed_lm.py`` and the
fault-tolerance tests.  On the container it runs on the 1-device mesh;
on a pod the identical object runs under ``make_production_mesh()`` —
the mesh and the dp extent are constructor parameters, everything else
(sampler addressing, checkpoint format, step function) is mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import BatchLoader, IndexedDataset
from repro.data.sampler import GlobalSampler
from repro.dist.compress import ErrorFeedbackCompressor, make_compressor
from repro.models.registry import ModelApi, build_model
from repro.runtime.fault import Heartbeat
from repro.train.loop import make_train_state, make_train_step
from repro.train.optimizer import AdamWConfig

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    seq_len: int = 128
    global_batch: int = 8
    steps: int = 50
    ckpt_every: int = 10
    keep_last: int = 3
    grad_accum: int = 1
    compress_grads: bool = False
    # compression scheme when compress_grads is set — a repro.dist.compress
    # registry name ("int8_ef", "int8_pc_ef", "topk_ef"); topk_frac only
    # applies to topk.
    compressor: str = "int8_ef"
    topk_frac: float = 0.1
    seed: int = 0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)

    def make_compressor(self) -> Optional[ErrorFeedbackCompressor]:
        """The configured gradient compressor, or None when disabled."""
        if not self.compress_grads:
            return None
        return make_compressor(self.compressor, topk_frac=self.topk_frac)


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        tcfg: TrainerConfig,
        dataset: IndexedDataset,
        workdir: Path,
        mesh=None,
        dp_rank: int = 0,
        n_dp: int = 1,
    ):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.dataset = dataset
        self.workdir = Path(workdir)
        self.mesh = mesh
        self.dp_rank = dp_rank
        self.n_dp = n_dp
        self.api = build_model(model_cfg)
        self.sampler = GlobalSampler(
            n_examples=len(dataset),
            global_batch=tcfg.global_batch,
            seed=tcfg.seed,
        )
        self.ckpt = CheckpointManager(self.workdir / "ckpt", keep_last=tcfg.keep_last)
        self.heartbeat = Heartbeat(self.workdir, dp_rank)
        self._compressor = tcfg.make_compressor()
        self._step_fn = jax.jit(
            make_train_step(self.api, tcfg.opt, tcfg.grad_accum, self._compressor),
            donate_argnums=(0,),
        )

    # -- state --------------------------------------------------------------

    def init_state(self) -> Dict[str, Any]:
        state = make_train_state(self.api, jax.random.PRNGKey(self.tcfg.seed), self.tcfg.opt)
        if self._compressor is not None:
            state[self._compressor.state_key] = self._compressor.init(
                state["params"]
            )
        return state

    def maybe_restore(self, state: Dict[str, Any]) -> Tuple[int, Dict[str, Any]]:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, state
        step, restored = self.ckpt.restore(state)
        restored = jax.tree_util.tree_map(jnp.asarray, restored)
        return step, restored

    # -- run ----------------------------------------------------------------

    def run(
        self,
        until_step: Optional[int] = None,
        state: Optional[Dict[str, Any]] = None,
        on_step: Optional[Callable[[int, dict], None]] = None,
        die_at_step: Optional[int] = None,
    ) -> Tuple[int, Dict[str, Any], list]:
        """Train from the latest checkpoint (or ``state``) to ``until_step``.

        ``die_at_step`` simulates a node failure: the trainer stops without
        a final checkpoint, exactly like a SIGKILL (recovery must come from
        the last periodic checkpoint).

        Runs inside the trainer's mesh context (when one was given), so
        the step function traces with the logical sharding rules active —
        every ``constrain`` in the model resolves against this mesh.
        """
        with self.mesh if self.mesh is not None else contextlib.nullcontext():
            return self._run(until_step, state, on_step, die_at_step)

    def _run(self, until_step, state, on_step, die_at_step):
        until = until_step if until_step is not None else self.tcfg.steps
        if state is None:
            start, state = self.maybe_restore(self.init_state())
        else:
            start = int(state["step"])
        history = []
        for step in range(start, until):
            batch_np = self.dataset.batch_for(
                self.sampler, step, self.dp_rank, self.n_dp
            )
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            loss = float(metrics["loss"])
            rec = {
                "step": step,
                "loss": loss,
                "grad_norm": float(metrics["grad_norm"]),
                "lr": float(metrics["lr"]),
                "dt": time.perf_counter() - t0,
            }
            history.append(rec)
            self.heartbeat.beat(step)
            if on_step:
                on_step(step, rec)
            done = step + 1
            if die_at_step is not None and done >= die_at_step:
                return done, state, history  # crashed: no checkpoint written
            if done % self.tcfg.ckpt_every == 0 or done == until:
                self.ckpt.save(done, state, meta={"loss": loss}, blocking=True)
        return until, state, history
