"""Sharded AdamW + LR schedules (no external deps — optax is not vendored).

Optimizer state mirrors the parameter pytree (m, v in fp32), so GSPMD
shards it exactly like the FSDP/TP-sharded params — ZeRO-style partitioned
optimizer state falls out of the sharding rules with no extra code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # Reduced-precision first moment (standard at ≥100B scale): m tolerates
    # bf16 (it's a smoothed gradient); v stays fp32 (sqrt of tiny values).
    # Cuts optimizer residency from 8 to 6 bytes/param — the knob that
    # closes jamba-398B's fit gap (§Perf).
    m_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
        return cfg.lr * warm * frac

    return lr


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_init(params: PyTree, cfg: Optional[AdamWConfig] = None) -> PyTree:
    m_dt = jnp.bfloat16 if cfg and cfg.m_dtype == "bfloat16" else jnp.float32
    return {
        "m": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=m_dt), params
        ),
        "v": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    cfg: AdamWConfig,
    grads: PyTree,
    opt_state: PyTree,
    params: PyTree,
) -> Tuple[PyTree, PyTree, dict]:
    """One AdamW step with global-norm clipping; returns (params, state, info)."""
    count = opt_state["count"] + 1
    lr = cosine_schedule(cfg)(count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads
    )

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree_util.tree_map(
        lambda mm, g: (b1 * mm.astype(jnp.float32) + (1 - b1) * g).astype(
            mm.dtype
        ),
        opt_state["m"],
        grads,
    )
    v = jax.tree_util.tree_map(
        lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g), opt_state["v"], grads
    )
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, mm, vv):
        step = (mm.astype(jnp.float32) / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        return (
            p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        ).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    new_state = {"m": m, "v": v, "count": count}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
