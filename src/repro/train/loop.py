"""train_step / serve_step factories: the units the launcher pjits.

``make_train_step`` closes over the model API and optimizer config and
returns a pure ``(state, batch) → (state, metrics)`` function — exactly
what gets ``jax.jit``-ed with in/out shardings by the launcher and the
multi-pod dry-run.  Microbatch gradient accumulation (``grad_accum > 1``)
runs as a ``lax.scan`` over microbatches with an fp32 grad accumulator.

Optional gradient compression (int8 + error feedback) hooks in between
grad computation and the optimizer — see :mod:`repro.dist.compress`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.registry import ModelApi
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_state", "make_train_step", "make_serve_step"]

PyTree = Any


def make_train_state(
    api: ModelApi, key, opt_cfg: Optional[AdamWConfig] = None
) -> Dict[str, Any]:
    params, _ = api.init(key)
    return {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    api: ModelApi,
    opt_cfg: AdamWConfig,
    grad_accum: int = 1,
    compressor: Optional[Any] = None,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``."""

    def loss_fn(params, batch):
        loss, metrics = api.loss(params, batch)
        return loss, metrics

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        # split the global batch into microbatches along axis 0
        def slice_mb(x, i):
            mb = x.shape[0] // grad_accum
            return lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, loss_acc = carry
            mb = jax.tree_util.tree_map(lambda x: slice_mb(x, i), batch)
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads
            )
            return (acc, loss_acc + loss), metrics

        from repro import flags

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss_sum), metrics = lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(grad_accum),
            unroll=flags.scan_unroll(),
        )
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return loss_sum / grad_accum, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        if compressor is not None:
            grads, state = compressor.apply(grads, state)
        params, opt, info = adamw_update(
            opt_cfg, grads, state["opt"], state["params"]
        )
        new_state = dict(state)
        new_state.update(params=params, opt=opt, step=state["step"] + 1)
        out = {"loss": loss, **metrics, **info}
        return new_state, out

    return train_step


def make_serve_step(api: ModelApi):
    """Returns ``serve_step(params, token, pos, cache) -> (logits, cache)``."""

    def serve_step(params, token, pos, cache):
        return api.decode_step(params, token, pos, cache)

    return serve_step
