import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
    " --xla_force_host_platform_device_count=512"
).strip()
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell: build the production mesh (16×16 single-pod, 2×16×16
multi-pod) over 512 placeholder host devices, assemble NamedShardings from
the models' logical param specs, then

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…,
                           donate_argnums=…).lower(*ShapeDtypeStructs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

No arrays are allocated — state, caches and inputs are ``jax.eval_shape``
/ ``ShapeDtypeStruct`` stand-ins.

Roofline terms: XLA's cost_analysis counts a while-loop body ONCE
regardless of trip count, so the scanned full-depth compile under-reports
FLOPs by ~n_layers.  We therefore also compile 1-block and 2-block
UNROLLED probe variants of the same cell and extrapolate exactly
(uniform stacks ⇒ cost(L) = base + L·Δ).  The full-depth compile remains
the shardability + memory_analysis proof.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--both-meshes] --out results/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import flags
from repro.configs import (
    ARCH_NAMES,
    SHAPES,
    cell_is_runnable,
    get_config,
    shape_by_name,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    HW,
    RooflineTerms,
    model_flops,
    roofline_from_compiled,
)
from repro.launch.sharding import batch_shardings, shardings_from_specs
from repro.models.registry import build_model
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig


def abstract_init(api):
    """(param ShapeDtypeStructs, logical specs) with zero allocation."""
    box = {}

    def trace_me(key):
        params, specs = api.init(key)
        box["specs"] = specs
        return params

    params_struct = jax.eval_shape(
        trace_me, jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    return params_struct, box["specs"]


def abstract_cache(api, batch: int, max_len: int):
    box = {}

    def trace_me():
        cache, spec = api.cache_init(batch, max_len)
        box["spec"] = spec
        return cache

    return jax.eval_shape(trace_me), box["spec"]


def param_stats(params_struct, specs) -> dict:
    total = 0
    expert = 0

    def walk(p, s):
        nonlocal total, expert
        total += p.size
        if isinstance(s, tuple) and "experts" in s:
            expert += p.size

    jax.tree_util.tree_map(
        walk, params_struct, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return {"total": int(total), "expert": int(expert)}


def _scan_unit(cfg) -> int:
    """Layers per scan step (the linearity unit for probe extrapolation)."""
    if cfg.local_block:
        return cfg.local_block
    if cfg.hybrid_block:
        return cfg.hybrid_block
    return 1


def _probe_cfg(cfg, units: int):
    per = _scan_unit(cfg)
    changes = {"n_layers": per * units}
    if cfg.n_enc_layers:
        changes["n_enc_layers"] = max(
            1, cfg.n_enc_layers * (per * units) // cfg.n_layers
        )
    return dataclasses.replace(cfg, **changes)


def build_lowered(cfg, shape, mesh):
    """Assemble shardings and lower the cell's step function.

    REPRO_SERVE_LAYOUT=1 switches prefill/decode cells to the SERVING
    param layout (§Perf iteration 3): bf16 weights, dense weights
    replicated over the data axis (no per-step FSDP all-gather; MoE expert
    banks keep their data shard — too large to replicate).  Default is the
    training layout: right whenever weights+cache approach HBM (see
    EXPERIMENTS.md §Perf for the measured trade).
    """

    api = build_model(cfg)
    params_struct, param_specs = abstract_init(api)
    serve_layout = (
        shape.kind in ("prefill", "decode")
        and os.environ.get("REPRO_SERVE_LAYOUT", "0") == "1"
    )
    if serve_layout:
        # bf16 serving weights (float leaves only)
        params_struct = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape,
                jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype,
            ),
            params_struct,
        )
        if cfg.n_experts:
            # keep expert banks data-sharded; replicate only dense weights
            def _serve_spec(spec):
                if isinstance(spec, tuple) and "experts" in spec:
                    return spec
                return tuple(None if s == "embed" else s for s in spec) \
                    if isinstance(spec, tuple) else spec
            param_specs = jax.tree_util.tree_map(
                _serve_spec, param_specs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
        else:
            param_specs = jax.tree_util.tree_map(
                lambda sp: tuple(None if s == "embed" else s for s in sp)
                if isinstance(sp, tuple) else sp,
                param_specs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
    param_sh = shardings_from_specs(mesh, param_specs, params_struct)
    from jax.sharding import NamedSharding, PartitionSpec as P

    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        grad_accum = int(os.environ.get("REPRO_DRYRUN_GRAD_ACCUM", "1"))
        m_dtype = os.environ.get("REPRO_DRYRUN_M_DTYPE", "float32")
        opt_cfg = AdamWConfig(m_dtype=m_dtype)
        step = make_train_step(api, opt_cfg, grad_accum=grad_accum)
        state_struct = jax.eval_shape(
            lambda p: {
                "params": p,
                "opt": {
                    "m": jax.tree_util.tree_map(
                        lambda x: jnp.zeros(
                            x.shape,
                            jnp.bfloat16 if m_dtype == "bfloat16"
                            else jnp.float32,
                        ),
                        p,
                    ),
                    "v": jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), p
                    ),
                    "count": jnp.zeros((), jnp.int32),
                },
                "step": jnp.zeros((), jnp.int32),
            },
            params_struct,
        )
        state_sh = {
            "params": param_sh,
            "opt": {"m": param_sh, "v": param_sh, "count": repl},
            "step": repl,
        }
        in_specs = api.input_specs(shape)
        batch_sh = batch_shardings(mesh, in_specs)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, repl),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_struct, in_specs)
    elif shape.kind == "prefill":
        in_specs = api.input_specs(shape)
        batch_sh = batch_shardings(mesh, in_specs)

        def prefill_fn(params, batch):
            return api.prefill(params, batch, max_len=shape.seq_len)

        jitted = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        lowered = jitted.lower(params_struct, in_specs)
    else:  # decode
        cache_struct, cache_spec = abstract_cache(
            api, shape.global_batch, shape.seq_len
        )
        cache_sh = shardings_from_specs(mesh, cache_spec, cache_struct)
        in_specs = api.input_specs(shape)
        batch_sh = batch_shardings(mesh, in_specs)

        def serve_step(params, token, pos, cache):
            return api.decode_step(params, token, pos, cache)

        jitted = jax.jit(
            serve_step,
            in_shardings=(param_sh, batch_sh["token"], batch_sh["pos"], cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(3,),
        )
        lowered = jitted.lower(
            params_struct, in_specs["token"], in_specs["pos"], cache_struct
        )
    pstats = param_stats(params_struct, param_specs)
    return lowered, pstats


def probe_roofline(cfg, shape, mesh) -> dict:
    """1-block / 2-block unrolled probe compiles → exact extrapolated terms."""
    per = _scan_unit(cfg)
    n_units = cfg.n_layers // per
    with flags.unroll_scans():
        t1, _ = _compile_terms(_probe_cfg(cfg, 1), shape, mesh)
        if n_units > 1:
            t2, _ = _compile_terms(_probe_cfg(cfg, 2), shape, mesh)
        else:
            t2 = t1
    def extrap(a, b):
        return a + (n_units - 1) * (b - a)

    coll_bd = {
        k: int(extrap(t1.collective_breakdown.get(k, 0),
                      t2.collective_breakdown.get(k, 0)))
        for k in set(t1.collective_breakdown) | set(t2.collective_breakdown)
    }
    return RooflineTerms(
        flops_per_device=extrap(t1.flops_per_device, t2.flops_per_device),
        bytes_per_device=extrap(t1.bytes_per_device, t2.bytes_per_device),
        collective_bytes=extrap(t1.collective_bytes, t2.collective_bytes),
        collective_breakdown=coll_bd,
        peak_memory_bytes=0.0,
    )


def _compile_terms(cfg, shape, mesh):
    lowered, pstats = build_lowered(cfg, shape, mesh)
    compiled = lowered.compile()
    return roofline_from_compiled(compiled), pstats


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
               skip_probes: bool = False):
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not cell_is_runnable(cfg, shape):
        rec.update(status="skipped", reason=cfg.notes)
        print(f"[{mesh_name}] {arch} × {shape_name}: SKIPPED ({cfg.notes})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    with mesh:
        lowered, pstats = build_lowered(cfg, shape, mesh)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        try:
            mem_str = str(compiled.memory_analysis())
        except Exception as e:  # pragma: no cover
            mem_str = f"<unavailable: {e}>"
        scanned_terms = roofline_from_compiled(compiled)

        if skip_probes:
            terms = scanned_terms
        else:
            terms = probe_roofline(cfg, shape, mesh)
            terms.peak_memory_bytes = scanned_terms.peak_memory_bytes

    n_active = pstats["total"] - pstats["expert"] + (
        pstats["expert"] * cfg.experts_per_token // max(cfg.n_experts, 1)
    )
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(n_active, tokens, "train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(n_active, tokens, "inference")
    else:
        tokens = shape.global_batch
        mf = model_flops(n_active, tokens, "inference")

    n_chips = 512 if multi_pod else 256
    rec.update(
        status="ok",
        n_chips=n_chips,
        params_total=pstats["total"],
        params_active=int(n_active),
        tokens_per_step=int(tokens),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        total_s=round(time.perf_counter() - t0, 2),
        memory_analysis=mem_str,
        model_flops_global=mf,
        model_flops_per_device=mf / n_chips,
        roofline=terms.as_dict(),
        roofline_scanned_raw=scanned_terms.as_dict(),
    )
    rec["useful_flops_ratio"] = (
        (mf / n_chips) / terms.flops_per_device if terms.flops_per_device else None
    )
    if verbose:
        r = terms
        print(
            f"[{mesh_name}] {arch} × {shape_name}: OK "
            f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
            f"total {rec['total_s']:.1f}s)\n"
            f"  params={pstats['total']/1e9:.2f}B active={n_active/1e9:.2f}B "
            f"tokens/step={tokens} useful_ratio="
            f"{rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}\n"
            f"  per-device: flops={r.flops_per_device:.3e} "
            f"bytes={r.bytes_per_device:.3e} coll={r.collective_bytes:.3e}\n"
            f"  terms(s): compute={r.t_compute:.4f} memory={r.t_memory:.4f} "
            f"collective={r.t_collective:.4f} → bottleneck={r.bottleneck}\n"
            f"  memory_analysis: {mem_str[:260]}",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every runnable cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-probes", action="store_true",
                    help="skip unrolled probe compiles (raw scanned costs only)")
    ap.add_argument("--out", type=str, default=None, help="JSONL output path")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    out_f = None
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        out_f = open(args.out, "a")
    failures = 0
    for mp in meshes:
        for arch, shp in cells:
            try:
                rec = lower_cell(arch, shp, mp, skip_probes=args.skip_probes)
            except Exception:
                rec = {
                    "arch": arch, "shape": shp,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error", "trace": traceback.format_exc(),
                }
                failures += 1
                print(f"[{'2x16x16' if mp else '16x16'}] {arch} × {shp}: FAILED")
                print(rec["trace"].splitlines()[-1], flush=True)
            if out_f:
                out_f.write(json.dumps(rec) + "\n")
                out_f.flush()
    if out_f:
        out_f.close()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
