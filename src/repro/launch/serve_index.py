"""Index-serving launcher: ``python -m repro.launch.serve_index …``.

Stands up the full query-service stack — ShardRouter replicas over a
published sharded store, the continuous micro-batching scheduler, the
pipelined reader with the shared scan-resistant record cache — and
drives it with a closed-loop load, reporting sustained lookups/sec,
p50/p99 latency, coalesced batch sizes, and cache/Bloom counters, plus
the naive per-key baseline for comparison.

    # demo corpus + store, 8 clients x 4-key requests, 2 replicas
    python -m repro.launch.serve_index --records 24000 --clients 8

    # serve an existing store (built with ByteOffsetIndex.save_sharded)
    python -m repro.launch.serve_index --store runs/index_store \\
        --corpus runs/corpus --replicas 4 --max-batch 512 --max-wait-ms 1

``--skip-naive`` drops the baseline pass; ``--keys-per-request 1``
measures the pure request-coalescing regime (each client request is a
single key, so the entire win must come from cross-client batching).

``--similarity`` switches the load to the second query modality: each
client request is a batch of query fingerprints answered with
``QueryService.similar`` (batched Tanimoto top-``--similar-k`` over the
store's fingerprint planes, coalesced across clients), against a naive
one-query-at-a-time baseline, with a parity gate asserting the service
path matches per-query scoring exactly.

``--chaos`` wraps every replica endpoint in a seeded
:class:`~repro.service.transport.FaultInjectingTransport` and drives the
closed-loop load through injected faults: a shard killed on every
replica mid-run (``--chaos-kill-shard`` / ``--chaos-kill-at``), revived
later (``--chaos-revive-at``), optional per-shard latency spikes
(``--chaos-latency-shard`` / ``--chaos-latency-ms``) and transient error
rates (``--chaos-flaky-rate``).  The report separates failed vs degraded
requests, shows hedges fired / retries / per-shard error taxonomy, and
gates on full post-revival parity against a clean store.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import IndexStore, RecordStore, build_index, extract
from repro.core.fingerprint import fingerprint_batch
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.runtime.fault import BackoffPolicy
from repro.service import (
    FaultInjectingTransport,
    LocalTransport,
    QueryService,
    ServiceConfig,
    ShardRouter,
    run_closed_loop,
)

# places distros drop tcmalloc; probed in order, first hit wins
_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def _maybe_preload_tcmalloc() -> None:
    """Re-exec under tcmalloc when the library is present.

    The span engine's carve/decode path allocates from several threads at
    once; glibc malloc's arena locking shows up as serving jitter there.
    tcmalloc's thread-local caches remove it.  Opt out with
    ``REPRO_NO_TCMALLOC=1``; the ``_REPRO_TCMALLOC`` guard keeps the
    re-exec from recursing, and boxes without the library run as-is.
    """
    if os.environ.get("REPRO_NO_TCMALLOC") or os.environ.get("_REPRO_TCMALLOC"):
        return
    if "tcmalloc" in os.environ.get("LD_PRELOAD", ""):
        return
    for so in _TCMALLOC_CANDIDATES:
        if os.path.exists(so):
            env = dict(os.environ)
            env["LD_PRELOAD"] = ":".join(
                p for p in (env.get("LD_PRELOAD", ""), so) if p
            )
            env["_REPRO_TCMALLOC"] = "1"
            os.execve(sys.executable, [sys.executable] + sys.argv, env)


def _demo_store(records: int, files: int, n_shards: int):
    """Generate a demo corpus + published store under a temp dir."""
    spec = CorpusSpec(n_files=files, records_per_file=records // files)
    root = Path(tempfile.mkdtemp(prefix="serve_index_")) / "corpus"
    generate_corpus(root, spec)
    rstore = RecordStore(root)
    idx = build_index(rstore, key_mode="full_id")
    store_dir = root.parent / "index_store"
    idx.save_sharded(store_dir, n_shards=n_shards)
    return rstore, store_dir, spec


def _similarity_load(svc, store_dir, keys, args) -> None:
    """The ``--similarity`` closed-loop: batched Tanimoto vs per-query naive."""
    bits = svc.router.fingerprint_bits
    if bits is None:
        raise SystemExit(
            "store has no fingerprint plane — republish with "
            "save_sharded(fingerprint_bits=...) to serve similarity"
        )
    k = args.similar_k
    print(f"similarity mode: {bits}-bit fingerprints, top-{k} per query")
    fps, _ = fingerprint_batch(keys, bits)
    pool = list(fps)

    if not args.skip_parity:
        sample = fps[:: max(1, len(fps) // 64)][:64]
        svc_out = svc.similar(sample, k)
        ref_store = IndexStore.open(store_dir)
        naive_out = [
            ref_store.similar_batch(sample[i:i + 1], k, probe="host")
            for i in range(len(sample))
        ]
        for col in range(3):
            merged = np.concatenate([p[col] for p in naive_out], axis=0)
            assert np.array_equal(svc_out[col], merged), (
                "similarity parity failure: coalesced service results "
                "differ from per-query scoring"
            )
        print(f"parity: svc.similar == per-query similar_batch on "
              f"{len(sample)} queries ✓")

    if not args.skip_naive:
        naive_store = IndexStore.open(store_dir)
        naive_store.similar_batch(fps[:1], k, probe="host")  # warm planes

        def naive(rows):  # pre-batching contract: one scan per query
            for r in rows:
                naive_store.similar_batch(
                    np.asarray(r)[None, :], k, probe="host"
                )

        rep_naive = run_closed_loop(
            naive, pool, clients=args.clients, duration_s=args.seconds,
            keys_per_request=args.keys_per_request,
        )
        print(f"naive  : {rep_naive.summary()}")

    svc.similar(fps[: min(64, len(pool))], k)  # warm planes + batcher
    rep_svc = run_closed_loop(
        lambda rows: svc.similar(np.stack(rows), k), pool,
        clients=args.clients, duration_s=args.seconds,
        keys_per_request=args.keys_per_request,
    )
    print(f"service: {rep_svc.summary()}")
    if not args.skip_naive:
        print(f"speedup: {rep_svc.lookups_per_sec / max(rep_naive.lookups_per_sec, 1e-9):.2f}x "
              f"sustained similarity queries/s vs naive per-query scans")

    sim = svc.stats()["similarity"]
    sched = sim["scheduler"] or {}
    print(f"similarity: {sim['batches']} router batches / "
          f"{sim['queries']} queries ({sim['scattered']} scattered, "
          f"{sim['inline']} inline, {sim['shard_probes']} shard probes), "
          f"{sim['fp_rows_scanned'] / 1e6:.1f}M row-pairs scored")
    if sched:
        print(f"scheduler: {sched['batches']} probes / "
              f"{sched['requests']} requests, mean batch "
              f"{sched['mean_batch_keys']:.1f} queries; latency "
              f"p50={sched['latency_ms']['p50']:.2f}ms "
              f"p99={sched['latency_ms']['p99']:.2f}ms")


def _chaos_load(svc, injectors, store_dir, keys, args) -> None:
    """The ``--chaos`` closed-loop: injected faults under live load.

    The invariants this run demonstrates (and asserts):

    * clients see ZERO exceptions — a dead shard range degrades, it does
      not fail the request;
    * degraded responses carry the per-key ``degraded`` mask, so callers
      can distinguish "absent" from "unknown";
    * after the revive point, full parity with a clean store returns
      within the recovery window (health probation + backoff).
    """
    rt = svc.router
    print(
        f"chaos: seed {args.chaos_seed}; kill shard {args.chaos_kill_shard} "
        f"on every replica at t+{args.chaos_kill_at:.1f}s, revive at "
        f"t+{args.chaos_revive_at:.1f}s"
        + (f"; +{args.chaos_latency_ms:.0f}ms latency on shard "
           f"{args.chaos_latency_shard}"
           if args.chaos_latency_shard is not None else "")
        + (f"; flaky rate {args.chaos_flaky_rate:.0%}"
           if args.chaos_flaky_rate > 0 else "")
    )
    if args.chaos_latency_shard is not None:
        for tr in injectors:
            tr.set_latency(
                args.chaos_latency_ms,
                jitter_ms=args.chaos_latency_ms / 3,
                shard=args.chaos_latency_shard,
            )
    if args.chaos_flaky_rate > 0:
        for tr in injectors:
            tr.set_error_rate(args.chaos_flaky_rate)

    svc.lookup_batch(keys[: min(2000, len(keys))])  # warm

    events = []

    def driver():
        t0 = time.perf_counter()
        time.sleep(args.chaos_kill_at)
        for tr in injectors:
            tr.kill(shard=args.chaos_kill_shard)
        events.append(("kill", time.perf_counter() - t0))
        time.sleep(max(0.0, args.chaos_revive_at - args.chaos_kill_at))
        for tr in injectors:
            tr.revive(shard=args.chaos_kill_shard)
        events.append(("revive", time.perf_counter() - t0))

    th = threading.Thread(target=driver, daemon=True)
    th.start()
    rep = run_closed_loop(
        lambda ks: svc.lookup_batch(ks), keys,
        clients=args.clients, duration_s=args.seconds,
        keys_per_request=args.keys_per_request,
        classify=lambda r: bool(r.degraded.any()),
        counters_fn=lambda: {
            "hedges_fired": rt.stats.hedges_fired,
            "hedge_wins": rt.stats.hedge_wins,
            "retries": rt.stats.retries,
            "probes_failed": rt.stats.probes_failed,
            "degraded_keys": rt.stats.degraded_keys,
        },
    )
    th.join(timeout=args.chaos_revive_at + 10)
    print(f"service: {rep.summary()}")
    c = rep.counters
    print(
        f"chaos:   {rep.errors} failed / {rep.degraded} degraded of "
        f"{rep.requests} requests; hedges {c.get('hedges_fired', 0)} "
        f"(won {c.get('hedge_wins', 0)}), retries {c.get('retries', 0)}, "
        f"probes failed {c.get('probes_failed', 0)}, degraded keys "
        f"{c.get('degraded_keys', 0)}"
    )
    errs = rt.stats.errors_per_shard
    if errs:
        print("chaos:   error taxonomy per shard: "
              + ", ".join(f"s{s}={dict(e)}" for s, e in sorted(errs.items())))
    assert rep.errors == 0, (
        f"{rep.errors} requests raised to clients — degraded mode must "
        f"return partial results, not exceptions"
    )

    # recovery gate: full parity with a clean store within the window
    sample = keys[:: max(1, len(keys) // 500)]
    ref = IndexStore.open(store_dir)
    want = ref.lookup_batch(sample)
    t_revive = time.perf_counter()
    deadline = t_revive + args.chaos_recovery_s
    got = svc.lookup_batch(sample)
    while got.degraded.any() and time.perf_counter() < deadline:
        time.sleep(0.2)
        got = svc.lookup_batch(sample)
    recovered_in = time.perf_counter() - t_revive
    assert not got.degraded.any(), (
        f"degraded responses persisted {args.chaos_recovery_s:.0f}s after "
        f"revival"
    )
    for a, b in zip((got.file_ids, got.offsets, got.hit), want):
        assert np.array_equal(a, b), "post-revival results differ from clean store"
    snap = rt.health.snapshot()
    print(
        f"chaos:   post-revival parity on {len(sample)} keys ✓ "
        f"(re-probed clean {recovered_in:.2f}s after revive; "
        f"{snap['revivals']} domain revivals, last recovery "
        f"{snap['last_recovery_s']:.2f}s)"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", help="published store dir (save_sharded)")
    ap.add_argument("--corpus", help="SDF corpus dir backing --store")
    ap.add_argument("--records", type=int, default=24_000,
                    help="demo corpus size when --store is omitted")
    ap.add_argument("--files", type=int, default=6)
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=512)
    ap.add_argument("--max-wait-ms", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--keys-per-request", type=int, default=4)
    ap.add_argument("--seconds", type=float, default=2.0)
    ap.add_argument("--skip-naive", action="store_true")
    ap.add_argument("--skip-parity", action="store_true")
    ap.add_argument("--similarity", action="store_true",
                    help="drive the Tanimoto similarity modality instead "
                         "of exact-key lookups")
    ap.add_argument("--similar-k", type=int, default=8,
                    help="top-k per similarity query (--similarity mode)")
    ap.add_argument("--chaos", action="store_true",
                    help="wrap replicas in fault-injecting transports and "
                         "drive the load through a kill/revive cycle")
    ap.add_argument("--chaos-kill-shard", type=int, default=0,
                    help="shard hard-downed on every replica mid-run")
    ap.add_argument("--chaos-kill-at", type=float, default=0.5,
                    help="seconds into the run when the shard dies")
    ap.add_argument("--chaos-revive-at", type=float, default=1.2,
                    help="seconds into the run when the shard revives")
    ap.add_argument("--chaos-latency-shard", type=int, default=None,
                    help="shard given an injected latency spike from t0")
    ap.add_argument("--chaos-latency-ms", type=float, default=30.0)
    ap.add_argument("--chaos-flaky-rate", type=float, default=0.0,
                    help="transient per-probe error rate on every shard")
    ap.add_argument("--chaos-seed", type=int, default=42)
    ap.add_argument("--chaos-recovery-s", type=float, default=10.0,
                    help="post-revival window in which full parity must "
                         "return")
    ap.add_argument("--reader-backend", default=None,
                    choices=["auto", "uring", "thread", "mmap", "serial"],
                    help="span I/O backend (default: REPRO_READER_BACKEND "
                         "env or auto)")
    ap.add_argument("--reader-depth", type=int, default=None,
                    help="max in-flight spans per file read "
                         "(default: REPRO_READER_DEPTH env or 32)")
    args = ap.parse_args()
    _maybe_preload_tcmalloc()

    if args.store:
        store_dir = Path(args.store)
        rstore = RecordStore(Path(args.corpus)) if args.corpus else None
    else:
        print(f"no --store given: generating a {args.records}-record demo "
              f"corpus ({args.files} files, {args.shards} shards)…")
        rstore, store_dir, _ = _demo_store(
            args.records, args.files, args.shards
        )

    cfg = ServiceConfig(
        replicas=args.replicas,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        reader_backend=args.reader_backend,
        reader_depth=args.reader_depth,
        similar_top_k=max(32, args.similar_k),
    )
    injectors = []
    if args.chaos:
        # chaos serving posture: wrap each replica endpoint, keep probe
        # deadlines tight and the dead-replica backoff short so the
        # kill/revive cycle resolves inside the run window
        def chaos_factory(st, i):
            tr = FaultInjectingTransport(
                LocalTransport(st, name=f"replica{i}"),
                seed=args.chaos_seed + i,
            )
            injectors.append(tr)
            return tr

        router = ShardRouter(
            store_dir,
            replicas=args.replicas,
            min_scatter_keys=cfg.min_scatter_keys,
            transport_factory=chaos_factory,
            probe_timeout_ms=250.0,
            fail_threshold=2,
            health_backoff=BackoffPolicy(base_s=0.2, cap_s=1.0),
        )
        svc = QueryService(rstore, router, cfg)
    else:
        svc = QueryService(rstore, store_dir, cfg)
    keys = sorted(svc.router.iter_keys())
    print(f"store: {len(svc):,} entries, {svc.router.n_shards} shards, "
          f"{args.replicas} replicas; load: {args.clients} closed-loop "
          f"clients x {args.keys_per_request} keys/request")

    if args.similarity:
        _similarity_load(svc, store_dir, keys, args)
        svc.close()
        return

    if args.chaos:
        _chaos_load(svc, injectors, store_dir, keys, args)
        svc.close()
        router.close()  # chaos router is launcher-owned, not service-owned
        return

    # parity gate: the service path must be byte-identical to the serial
    # reference before any throughput number means anything
    if rstore is not None and not args.skip_parity:
        sample = keys[:: max(1, len(keys) // 2000)]
        ref_idx = IndexStore.open(store_dir)
        serial = extract(rstore, ref_idx, sample, workers=0)
        res = svc.fetch(sample)
        assert list(res.records.items()) == list(serial.records.items())
        assert res.missing == serial.missing
        assert res.mismatches == serial.mismatches
        print(f"parity: svc.fetch == serial extract on {len(sample)} "
              f"targets ✓")

    if not args.skip_naive:
        naive_store = IndexStore.open(store_dir)
        naive_store.lookup_batch(keys[: min(2000, len(keys))])  # warm

        def naive(ks):  # the pre-service contract: one probe per key
            for k in ks:
                naive_store.lookup_batch([k])

        rep_naive = run_closed_loop(
            naive, keys, clients=args.clients, duration_s=args.seconds,
            keys_per_request=args.keys_per_request,
        )
        print(f"naive  : {rep_naive.summary()}")

    svc.lookup_batch(keys[: min(2000, len(keys))])  # warm
    rep_svc = run_closed_loop(
        lambda ks: svc.lookup_batch(ks), keys, clients=args.clients,
        duration_s=args.seconds, keys_per_request=args.keys_per_request,
    )
    print(f"service: {rep_svc.summary()}")
    if not args.skip_naive:
        print(f"speedup: {rep_svc.lookups_per_sec / max(rep_naive.lookups_per_sec, 1e-9):.2f}x "
              f"sustained lookups/s vs naive per-key probing")

    s = svc.stats()
    sch, cache, st = s["scheduler"], s["cache"], s["store"]
    print(f"scheduler: {sch['batches']} probes / {sch['requests']} requests, "
          f"mean batch {sch['mean_batch_keys']:.1f} keys (max "
          f"{sch['batch_keys_max']}), flushes full={sch['full_flushes']} "
          f"cohort={sch['cohort_flushes']} deadline={sch['deadline_flushes']} "
          f"immediate={sch['immediate_flushes']}")
    print(f"store: {st['bloom_rejects']} bloom rejects, "
          f"{st['verify_collisions']} digest collisions verified away, "
          f"{st['shards_touched']}/{svc.router.n_shards} shards touched")
    print(f"cache: {cache['hit_rate']:.0%} hit rate, "
          f"{cache['protected']} protected / {cache['probation']} probation "
          f"entries")
    rd = s["read"]
    print(f"read: backend={rd['backend']}, {rd['spans_read']} spans / "
          f"{rd['bytes_read'] / 1e6:.2f} MB for {rd['records']} records "
          f"(depth peak {rd['inflight_peak']}, {rd['cache_hits']} cache "
          f"hits); verify {rd['verify_records']} recs in "
          f"{rd['verify_batches']} batches (max {rd['verify_batch_max']})")
    svc.close()


if __name__ == "__main__":
    main()
