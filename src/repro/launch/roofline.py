"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device      / peak_FLOP/s    (197 TF bf16/chip)
  memory     = HLO_bytes_per_device      / HBM_bw         (819 GB/s/chip)
  collective = collective_bytes_per_dev  / link_bw        (~50 GB/s/link)

``compiled.cost_analysis()`` reports the post-SPMD per-device program, so
all terms are per-chip; dividing per-chip quantities by per-chip rates is
algebraically identical to the global form  X_global / (chips × rate).
Collective bytes are not in cost_analysis — we parse the optimized HLO and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (including async -start forms, counted
once).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

__all__ = [
    "HW",
    "RooflineTerms",
    "collective_bytes_from_hlo",
    "roofline_from_compiled",
    "model_flops",
]


class HW:
    PEAK_FLOPS = 197e12          # bf16 per chip
    HBM_BW = 819e9               # bytes/s per chip
    ICI_BW = 50e9                # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes summed over the module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        kind = None
        for k in _COLLECTIVES:
            # match op name at call position; count async starts once
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue
        # operand shapes = every typed shape after the opening paren
        call = rhs.split("(", 1)[1]
        for m in _SHAPE_RE.finditer(call):
            out[kind] += _shape_bytes(m.group(1), m.group(2))
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_breakdown: Dict[str, int]
    peak_memory_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / HW.PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HW.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / HW.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Roofline lower bound on step time (terms fully overlapped)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "peak_memory_bytes": self.peak_memory_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time_lb,
        }


def roofline_from_compiled(compiled) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = 0.0
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes=float(sum(coll.values())),
        collective_breakdown=coll,
        peak_memory_bytes=peak,
    )


def model_flops(n_params_active: int, n_tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference forward)."""
    per_tok = 6 if kind == "train" else 2
    return float(per_tok) * n_params_active * n_tokens
