"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
smoke tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "mesh_from_str", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-carve after node loss, smoke meshes…)."""
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} / axes {axes} mismatch")
    return jax.make_mesh(shape, axes)


def mesh_from_str(spec: str):
    """``"DATAxMODEL"`` → mesh, or None for the 1-device ``"1x1"`` case.

    The launchers' shared CLI surface: validates the shape string so a
    typo fails with the expected format instead of an unpack traceback.
    """
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() for p in parts):
        raise ValueError(f"bad mesh {spec!r}; expected DATAxMODEL, e.g. 2x4")
    d, m = int(parts[0]), int(parts[1])
    if d < 1 or m < 1:
        raise ValueError(f"bad mesh {spec!r}; extents must be >= 1")
    if d * m == 1:
        return None
    return make_mesh((d, m), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
