"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
smoke tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-carve after node loss, smoke meshes…)."""
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} / axes {axes} mismatch")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
