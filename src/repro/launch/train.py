"""Training launcher: ``python -m repro.launch.train --arch <id> …``.

Wires the full stack for a real run: corpus/index data plane → model from
the assigned-architecture registry → sharded train step on the requested
mesh → catalog checkpoints + heartbeats.  On the CPU container the mesh is
(1,1) and the reduced smoke config is the default; on a pod, pass
``--full-config --mesh 16x16`` (the dry-run proves those lower).
"""

from __future__ import annotations

import argparse
import dataclasses
from pathlib import Path

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.core import RecordStore, build_index
from repro.core.sdfgen import CorpusSpec, generate_corpus
from repro.data.pipeline import IndexedDataset
from repro.launch.mesh import mesh_from_str
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="yi-6b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full published config (pod hardware)")
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--compressor", default="int8_ef",
                    choices=["int8_ef", "int8_pc_ef", "topk_ef"],
                    help="gradient compression scheme (with --compress-grads)")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="kept fraction for --compressor topk_ef")
    ap.add_argument("--workdir", default="runs/train")
    ap.add_argument("--corpus-records", type=int, default=4000)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    mesh = mesh_from_str(args.mesh)

    root = Path(args.workdir) / "corpus"
    spec = CorpusSpec(n_files=4, records_per_file=args.corpus_records // 4)
    generate_corpus(root, spec)
    store = RecordStore(root)
    ds = IndexedDataset(store, build_index(store, workers=2), args.seq_len)

    tcfg = TrainerConfig(
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        compressor=args.compressor,
        topk_frac=args.topk_frac,
        opt=AdamWConfig(warmup_steps=max(2, args.steps // 10),
                        total_steps=args.steps),
    )
    tr = Trainer(cfg, tcfg, ds, Path(args.workdir), mesh=mesh)

    def log(step, rec):
        if step % 5 == 0:
            print(f"step {step:5d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.2f} {rec['dt']*1e3:.0f} ms",
                  flush=True)

    # Trainer.run enters the mesh context itself (sharding rules active
    # while the step function traces).
    final, _, hist = tr.run(on_step=log)
    if hist:
        print(f"done: {final} steps, loss {hist[0]['loss']:.4f} → "
              f"{hist[-1]['loss']:.4f}, checkpoints at "
              f"{tr.ckpt.root} (latest {tr.ckpt.latest_step()})")
    else:  # resumed at or past --steps: nothing left to train
        print(f"done: already at step {final} (restored checkpoint), "
              f"checkpoints at {tr.ckpt.root} (latest {tr.ckpt.latest_step()})")


if __name__ == "__main__":
    main()
