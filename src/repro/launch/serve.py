"""Serving launcher: ``python -m repro.launch.serve --arch <id> …``.

Builds the engine for the requested architecture (reduced config on CPU;
the dry-run proves the full configs lower for the decode shapes) and
serves a batch of prompts, reporting prefill/decode timings.

``--mesh DATAxMODEL`` serves sharded: params go to their logical-rule
shardings (:mod:`repro.dist.logical`), the request batch spreads over the
data axis, and batched decode runs under the mesh so every ``constrain``
in the model takes effect.  The default ("1x1") stays single-device.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import mesh_from_str
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="yi-6b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--prompts", nargs="*", default=[
        "InChI=1S/C12H22O2/", "InChI=1S/C8H9NO2/",
    ])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    if cfg.family == "vlm":
        print("note: vlm frontend stubbed — serving text-only prompts")
    mesh = mesh_from_str(args.mesh)
    api = build_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))
    eng = Engine(
        cfg, params,
        ServeConfig(max_new_tokens=args.max_new_tokens, max_len=args.max_len),
        mesh=mesh, param_specs=specs,
    )
    print(f"serving {len(args.prompts)} prompts on {args.arch} "
          f"({'full' if args.full_config else 'smoke'} config, "
          f"mesh {args.mesh})…")
    for i, r in enumerate(eng.generate(args.prompts)):
        print(f"[{i}] prefill {r.prefill_s*1e3:.0f} ms, "
              f"{r.tokens_per_s:.1f} tok/s → {r.text[:60]!r}")


if __name__ == "__main__":
    main()
