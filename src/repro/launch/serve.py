"""Serving launcher: ``python -m repro.launch.serve --arch <id> …``.

Builds the engine for the requested architecture (reduced config on CPU;
the dry-run proves the full configs lower for the decode shapes) and
serves a batch of prompts, reporting prefill/decode timings.

``--mesh DATAxMODEL`` serves sharded: params go to their logical-rule
shardings (:mod:`repro.dist.logical`), the request batch spreads over the
data axis, and batched decode runs under the mesh so every ``constrain``
in the model takes effect.  The default ("1x1") stays single-device.

``--continuous`` serves through the paged-KV continuous-batching engine
instead (:mod:`repro.serve.scheduler`): prompts are submitted as
independent requests that admit into ``--max-slots`` decode lanes backed
by ``--block-size`` KV blocks, and the report adds the TTFT/inter-token
SLO percentiles plus the prefix-cache hit counters.  Prompts sharing a
block-aligned prefix share its KV via the prefix cache (on by default;
``--no-prefix-cache`` disables sharing — outputs are byte-identical
either way).  Continuous mode is single-device (``--mesh`` other than
1x1 is rejected rather than silently ignored).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import mesh_from_str
from repro.models.registry import build_model
from repro.serve.engine import Engine, ServeConfig
from repro.serve.kvcache import PagedCacheSpec, blocks_for
from repro.serve.scheduler import ContinuousEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="yi-6b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the paged-KV continuous-batching engine")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="decode batch width of the continuous engine")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV rows per paged-cache block")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share block-aligned prompt prefixes across "
                         "requests (continuous mode; byte-identical output "
                         "either way)")
    ap.add_argument("--prompts", nargs="*", default=[
        "InChI=1S/C12H22O2/", "InChI=1S/C8H9NO2/",
    ])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    if cfg.family == "vlm":
        print("note: vlm frontend stubbed — serving text-only prompts")
    api = build_model(cfg)
    params, specs = api.init(jax.random.PRNGKey(0))

    if args.continuous:
        if args.mesh != "1x1":
            raise SystemExit("--continuous serves single-device; drop --mesh")
        if not api.supports_paged:
            raise SystemExit(
                f"--arch {args.arch} has no paged-KV decode path "
                "(windowed attention or non-transformer family); "
                "drop --continuous")
        m = blocks_for(args.max_len, args.block_size)
        # headroom past full slot occupancy keeps prefix-index entries
        # resident between requests instead of thrashing under pressure
        headroom = m if args.prefix_cache else 0
        spec = PagedCacheSpec(
            n_blocks=args.max_slots * m + headroom + 2,  # + trash
            block_size=args.block_size,
            max_slots=args.max_slots,
            max_blocks_per_seq=m,
        )
        eng = ContinuousEngine(
            cfg, params, spec,
            ServeConfig(max_new_tokens=args.max_new_tokens,
                        max_len=spec.max_len),
            prefix_cache=args.prefix_cache,
        )
        print(f"serving {len(args.prompts)} prompts on {args.arch} "
              f"({'full' if args.full_config else 'smoke'} config, "
              f"continuous: {args.max_slots} slots x "
              f"{spec.max_blocks_per_seq} blocks of {args.block_size})…")
        for i, r in enumerate(eng.generate(args.prompts)):
            print(f"[{i}] prefill {r.prefill_s*1e3:.0f} ms, "
                  f"{r.tokens_per_s:.1f} tok/s → {r.text[:60]!r}")
        slo = eng.slo_ms()
        print(f"slo: ttft p50 {slo['ttft_p50_ms']:.1f} ms / "
              f"p99 {slo['ttft_p99_ms']:.1f} ms, itl p50 "
              f"{slo['itl_p50_ms']:.2f} ms / p99 {slo['itl_p99_ms']:.2f} ms")
        c = eng.counters()
        if "pfx_entries" in c:
            print(f"prefix cache: hit rate {c['prefix_hit_rate']:.2f} "
                  f"({c['prefix_hits']:.0f}/"
                  f"{c['prefix_hits'] + c['prefix_misses']:.0f}), "
                  f"{c['prefill_tokens_saved']:.0f} prefill tokens saved, "
                  f"{c['pfx_entries']:.0f} entries resident")
        else:
            print("prefix cache: off")
        eng.close()
        return

    mesh = mesh_from_str(args.mesh)
    eng = Engine(
        cfg, params,
        ServeConfig(max_new_tokens=args.max_new_tokens, max_len=args.max_len),
        mesh=mesh, param_specs=specs,
    )
    print(f"serving {len(args.prompts)} prompts on {args.arch} "
          f"({'full' if args.full_config else 'smoke'} config, "
          f"mesh {args.mesh})…")
    for i, r in enumerate(eng.generate(args.prompts)):
        print(f"[{i}] prefill {r.prefill_s*1e3:.0f} ms, "
              f"{r.tokens_per_s:.1f} tok/s → {r.text[:60]!r}")


if __name__ == "__main__":
    main()
