"""Sharding assembly: logical specs → NamedShardings for pjit boundaries."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.logical import current_rules, divisible_spec
from repro.launch.mesh import dp_axes

__all__ = [
    "shardings_from_specs",
    "batch_shardings",
    "state_shardings",
    "replicated",
]

PyTree = Any


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and all(s is None or isinstance(s, str) for s in x)


def shardings_from_specs(
    mesh: Mesh, specs: PyTree, shapes: Optional[PyTree] = None
) -> PyTree:
    """Map a pytree of logical-axis tuples to NamedShardings.

    With ``shapes`` (a matching pytree of arrays / ShapeDtypeStructs), mesh
    axes that don't divide the corresponding dim are dropped — and a
    ``kv_heads`` split that fails (GQA kv < model size) falls back to
    sharding the cache's sequence dim over "model" instead.
    """
    rules = current_rules()

    def one(spec, shape=None):
        p = rules.spec(spec, mesh)
        if shape is None:
            return NamedSharding(mesh, p)
        dims = shape.shape if hasattr(shape, "shape") else tuple(shape)
        q = divisible_spec(p, dims, mesh)
        # KV-cache fallback: if kv_heads couldn't take "model", give it to
        # the sequence dim (sequence-parallel KV — GSPMD inserts the
        # softmax partial reductions).
        if (
            "kv_heads" in spec
            and "model" in mesh.axis_names
            and all(pp != "model" for pp in q)
        ):
            ki = spec.index("kv_heads")
            parts = list(tuple(q) + (None,) * (len(dims) - len(tuple(q))))
            for j in range(ki + 1, len(dims)):
                if parts[j] is None and dims[j] % mesh.shape["model"] == 0 and dims[j] > 1:
                    parts[j] = "model"
                    break
            q = P(*parts)
        return NamedSharding(mesh, q)

    if shapes is None:
        return jax.tree_util.tree_map(one, specs, is_leaf=_is_spec)
    return jax.tree_util.tree_map(one, specs, shapes, is_leaf=_is_spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_shardings(mesh: Mesh, batch_specs: Dict[str, jax.ShapeDtypeStruct]):
    """Inputs: leading batch dim over dp axes, rest replicated.

    A batch dim that doesn't divide the dp extent (batch=1 long-context
    decode) is left replicated.
    """
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(sds):
        nd = len(sds.shape)
        if nd == 0 or sds.shape[0] % n_dp:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp_entry, *([None] * (nd - 1))))

    return {k: one(v) for k, v in batch_specs.items()}


def state_shardings(mesh: Mesh, param_specs: PyTree):
    """TrainState shardings: params + mirrored adam m/v + scalar step."""
    ps = shardings_from_specs(mesh, param_specs)
    return {
        "params": ps,
        "opt": {"m": ps, "v": ps, "count": replicated(mesh)},
        "step": replicated(mesh),
    }
