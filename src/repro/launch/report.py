"""Render EXPERIMENTS.md tables from dry-run JSONL records."""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path


def _temp_gb(rec) -> float:
    m = re.search(r"temp_size_in_bytes=(\d+)", rec.get("memory_analysis", ""))
    return int(m.group(1)) / 1e9 if m else float("nan")


def _args_gb(rec) -> float:
    m = re.search(r"argument_size_in_bytes=(\d+)", rec.get("memory_analysis", ""))
    return int(m.group(1)) / 1e9 if m else float("nan")


def roofline_table(path: Path, mesh: str = "16x16") -> str:
    rows = [json.loads(l) for l in open(path)]
    out = [
        "| arch × shape | bottleneck | t_comp (s) | t_mem (s) | t_coll (s) "
        "| MFU-bound | useful FLOPs | HBM args+temp (GB) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} × {r['shape']} | — skipped (long_500k needs "
                f"sub-quadratic attention) | | | | | | |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} × {r['shape']} | ERROR | | | | | | |")
            continue
        rf = r["roofline"]
        lb = rf["step_time_lb_s"]
        mfu = r["model_flops_per_device"] / (lb * 197e12) if lb else 0
        out.append(
            f"| {r['arch']} × {r['shape']} | {rf['bottleneck']} "
            f"| {rf['t_compute_s']:.3f} | {rf['t_memory_s']:.3f} "
            f"| {rf['t_collective_s']:.3f} | {mfu:.1%} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {_args_gb(r):.1f}+{_temp_gb(r):.1f} |"
        )
    return "\n".join(out)


def compare_table(base_path: Path, opt_path: Path, mesh: str = "16x16") -> str:
    def load(p):
        return {
            (r["arch"], r["shape"]): r
            for r in map(json.loads, open(p))
            if r.get("mesh") == mesh and r["status"] == "ok"
        }

    base, opt = load(base_path), load(opt_path)
    out = [
        "| cell | t_mem base→opt | t_coll base→opt | temp GB base→opt "
        "| MFU-bound base→opt |",
        "|---|---|---|---|---|",
    ]
    for key in base:
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        rb, ro = b["roofline"], o["roofline"]
        mfu_b = b["model_flops_per_device"] / (rb["step_time_lb_s"] * 197e12)
        mfu_o = o["model_flops_per_device"] / (ro["step_time_lb_s"] * 197e12)
        out.append(
            f"| {key[0]} × {key[1]} "
            f"| {rb['t_memory_s']:.2f}→{ro['t_memory_s']:.2f} "
            f"| {rb['t_collective_s']:.2f}→{ro['t_collective_s']:.2f} "
            f"| {_temp_gb(b):.1f}→{_temp_gb(o):.1f} "
            f"| {mfu_b:.1%}→{mfu_o:.1%} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    cmd = sys.argv[1]
    if cmd == "roofline":
        print(roofline_table(Path(sys.argv[2]), sys.argv[3] if len(sys.argv) > 3 else "16x16"))
    elif cmd == "compare":
        print(compare_table(Path(sys.argv[2]), Path(sys.argv[3]),
                            sys.argv[4] if len(sys.argv) > 4 else "16x16"))
