"""Global trace-time flags.

``unroll_scans`` — when set, layer-stack scans and the chunked-xent loop
are fully unrolled at trace time.  Used ONLY by the dry-run's roofline
probe compiles: XLA's ``cost_analysis`` counts a while-loop body once
regardless of trip count, so scanned stacks under-report FLOPs/bytes by a
factor of n_layers.  The probes compile 1-block and 2-block unrolled
variants and extrapolate exactly (stacks are uniform by construction).
Production code paths keep scans rolled (small HLO, fast compiles).
"""

from __future__ import annotations

import contextlib
import os

_UNROLL = False

# Attention implementation for the XLA (non-Pallas) path:
#   "chunked" — online-softmax scan over KV chunks (default; beyond-paper
#               §Perf optimization — no S×S score materialization)
#   "ref"     — unblocked reference (the paper-faithful framework baseline;
#               used for oracle tests and §Perf before/after runs)
ATTN_IMPL = os.environ.get("REPRO_ATTN_IMPL", "chunked")
ATTN_CHUNK = int(os.environ.get("REPRO_ATTN_CHUNK", "1024"))

# Sequence-parallel layer outputs (Megatron SP): constrain attention/MLP
# outputs to the sequence-sharded residual layout so GSPMD lowers the TP
# combine as reduce-scatter (half the wire bytes of all-reduce) and the
# norm/residual region computes seq-sharded.  §Perf optimization; set
# REPRO_SP_OUTPUTS=0 for the baseline layout.
SP_OUTPUTS = os.environ.get("REPRO_SP_OUTPUTS", "1") == "1"

# Chunked decode attention — off by default: under (batch, seq→model) cache
# sharding the chunk reshape reshards the cache (measured: collective term
# 0 → 3.4 s on qwen2 decode_32k).  See EXPERIMENTS.md §Perf.
DECODE_CHUNKED = os.environ.get("REPRO_DECODE_CHUNKED", "0") == "1"

# Remat policy for the layer scan:
#   "names"   — save attn/ffn outputs (post-TP-collective tensors): backward
#               does not re-run the forward all-reduces (≈⅓ of TP collective
#               bytes) nor the forward matmuls (8ND→6ND FLOPs), costing two
#               seq-sharded (B,S/model,D) saves per layer.  §Perf default.
#   "nothing" — full remat (the framework baseline).
REMAT_POLICY = os.environ.get("REPRO_REMAT_POLICY", "names")


# Read-engine knobs (read dynamically, not at import: tests and launchers
# flip them per run):
#   REPRO_READER_BACKEND — span I/O backend for core.reader / the service:
#       "auto"   — io_uring when the kernel supports it, else "thread"
#       "uring"  — raw io_uring submission queue (Linux; depth-controlled
#                  in-flight span windows, one enter() per window)
#       "thread" — synchronous preadv per span (the portable fallback)
#       "mmap"   — map whole files, serve records as zero-copy views of the
#                  page cache (no pread syscalls at all; opt-in: span/byte
#                  accounting semantics differ from the pread backends)
#   REPRO_READER_DEPTH — target in-flight spans per uring submission window
#       (default 32; clamped to the ring size).  Higher depths help cold
#       NVMe / networked storage; on a warm page cache it mostly bounds
#       buffer residency.
#   REPRO_VERIFY_BACKEND — id-recompute/compare mode for VerifyBatcher:
#       "auto" (vectorized recompute, digest compare on TPU else string),
#       "vector", "process" (fork-pool recompute off the GIL), "string" /
#       "digest" (per-record reference modes, combining disabled).


def reader_backend() -> str:
    return os.environ.get("REPRO_READER_BACKEND", "auto")


def reader_depth() -> int:
    return int(os.environ.get("REPRO_READER_DEPTH", "32"))


def verify_backend() -> str:
    return os.environ.get("REPRO_VERIFY_BACKEND", "auto")


def remat_policy():
    import jax

    if REMAT_POLICY == "names":
        return jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out", "mixer_out"
        )
    return jax.checkpoint_policies.nothing_saveable


def residual_axes():
    return ("batch", "seq_sp", None) if SP_OUTPUTS else ("batch", "seq", None)


def scan_unroll():
    """Value to pass to lax.scan(unroll=...)."""
    return True if _UNROLL else 1


def unrolling() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unroll_scans():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev
